"""Batched reconfiguration plan-search parity + incremental
invalidation tests (PR 4).

The batched engine (pre-scored per-fold offset tables, vectorized
single-cube search, fresh-cube bound pruning, dirty-cube cache
updates) must be behavior-preserving: identical plans and
byte-identical schedules versus the retained naive oracle, across cube
sizes, multi-cube offsets and release/re-place sequences."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import fitmask
from repro.core.allocator import make_policy
from repro.core.folding import enumerate_folds
from repro.core.geometry import JobShape
from repro.core.reconfig import ReconfigTorus, fold_plan_table
from repro.sim.simulator import Simulator
from repro.traces.generator import TraceConfig, generate_trace

CUBE_SIZES = [(512, 2), (512, 4), (4096, 8)]


def _random_fill(rt: ReconfigTorus, rng, steps=14):
    """Random occupancy via real commit/release traffic."""
    live = []
    jid = 0
    for _ in range(steps):
        if live and rng.uniform() < 0.4:
            rt.release(live.pop(int(rng.integers(len(live)))))
            continue
        dims = tuple(int(rng.integers(1, 9)) for _ in range(3))
        for f in enumerate_folds(JobShape(dims), max_dim=rt.max_extent):
            plan = rt.place_fold(f)
            if plan is not None:
                rt.commit(jid, plan)
                live.append(jid)
                jid += 1
                break
    return live


# ----------------------------------------------------- hypothesis sweep
@settings(max_examples=40, deadline=None)
@given(st.sampled_from(CUBE_SIZES),
       st.integers(0, 10_000),
       st.tuples(st.integers(1, 12), st.integers(1, 12),
                 st.integers(1, 12)),
       st.sampled_from([True, False]))
def test_place_fold_parity_sweep(size, seed, dims, offset_search):
    """Batched place_fold == naive oracle for every fold of a random
    shape on a randomly filled torus, across cube sizes and offset
    modes."""
    num_xpus, cube_n = size
    rng = np.random.default_rng(seed)
    rt = ReconfigTorus(num_xpus, cube_n)
    _random_fill(rt, rng, steps=10)
    for f in enumerate_folds(JobShape(dims), max_dim=rt.max_extent):
        assert rt.place_fold(f, offset_search=offset_search) == \
            rt.place_fold_naive(f, offset_search=offset_search), (dims, f)


@settings(max_examples=15, deadline=None)
@given(st.sampled_from(CUBE_SIZES), st.integers(0, 10_000))
def test_release_replace_sequence_parity(size, seed):
    """Interleaved commit/release traffic: after every mutation the
    batched search must agree with the naive oracle (the dirty-cube
    incremental refresh cannot drift from a from-scratch rebuild)."""
    num_xpus, cube_n = size
    rng = np.random.default_rng(seed)
    rt = ReconfigTorus(num_xpus, cube_n)
    probe_shapes = [(8, 4, 4), (6, 6, 1), (4, 4, 2), (2, 2, 2)]
    live = []
    jid = 0
    for _ in range(12):
        if live and rng.uniform() < 0.45:
            rt.release(live.pop(int(rng.integers(len(live)))))
        else:
            dims = tuple(int(rng.integers(1, 9)) for _ in range(3))
            for f in enumerate_folds(JobShape(dims),
                                     max_dim=rt.max_extent):
                plan = rt.place_fold(f)
                assert plan == rt.place_fold_naive(f)
                if plan is not None:
                    rt.commit(jid, plan)
                    live.append(jid)
                    jid += 1
                    break
        probe = JobShape(probe_shapes[int(rng.integers(len(probe_shapes)))])
        for f in enumerate_folds(probe, max_dim=rt.max_extent):
            assert rt.place_fold(f) == rt.place_fold_naive(f)
    rt.check_invariants()


@pytest.mark.parametrize("name", ["reconfig", "rfold", "rfold_be"])
@pytest.mark.parametrize("num_xpus,cube_n", CUBE_SIZES)
def test_schedule_parity_all_cube_sizes(name, num_xpus, cube_n):
    """Byte-identical schedules on a seeded trace: batched plan search
    + gated drain vs naive engine + ungated drain, at every cube
    size the paper evaluates."""
    cfg = TraceConfig(num_jobs=30, seed=13, target_load=1.8)
    fast = make_policy(name, num_xpus=num_xpus, cube_n=cube_n)
    res_fast = Simulator(fast, generate_trace(cfg), gated=True).run()
    naive = make_policy(name, num_xpus=num_xpus, cube_n=cube_n)
    naive.use_naive = True
    res_naive = Simulator(naive, generate_trace(cfg), gated=False).run()
    sig = lambda r: [(j.job_id, j.start, j.finish, j.dropped, j.slowdown,
                      j.placement_meta) for j in r.jobs]  # noqa: E731
    assert sig(res_fast) == sig(res_naive)
    assert res_fast.utilization_samples == res_naive.utilization_samples


def test_dedicate_chained_parity():
    """The chained-cube ablation flows through the fresh-bound prune
    (fresh == ncubes exactly for chained plans)."""
    rng = np.random.default_rng(3)
    rt = ReconfigTorus(512, 4, dedicate_chained=True)
    rt_ref = ReconfigTorus(512, 4, dedicate_chained=True)
    _random_fill(rt, rng, steps=8)
    rt_ref.occ[:] = rt.occ
    rt_ref.dedicated[:] = rt.dedicated
    rt_ref.bump_epoch()
    for dims in [(8, 4, 4), (16, 2, 2), (6, 6, 2), (4, 8, 2)]:
        for f in enumerate_folds(JobShape(dims), max_dim=rt.max_extent):
            assert rt.place_fold(f) == rt_ref.place_fold_naive(f), (dims, f)


# ------------------------------------------------- incremental refresh
@pytest.mark.parametrize("num_xpus,cube_n", [(4096, 2), (4096, 4)])
def test_dirty_cube_partial_refresh_matches_full(num_xpus, cube_n):
    """A commit touching few cubes takes the partial-refresh path (only
    dirty rows recomputed); derived state must equal a from-scratch
    rebuild."""
    rng = np.random.default_rng(7)
    rt = ReconfigTorus(num_xpus, cube_n)
    _random_fill(rt, rng, steps=10)
    shape = (2, 2, cube_n)
    rt._shape_fit_mask(shape)          # warm caches at this epoch
    fold = enumerate_folds(JobShape((2, 2, 2)), max_dim=rt.max_extent)[0]
    plan = rt.place_fold(fold)
    assert plan is not None
    rt.commit(12345, plan)             # marks only the touched cubes dirty
    assert rt._dirty                   # partial path is armed
    mask_after = rt._shape_fit_mask(shape).copy()
    cnt_after = rt._free_cnt.copy()

    fresh = ReconfigTorus(num_xpus, cube_n)
    fresh.occ[:] = rt.occ
    fresh.dedicated[:] = rt.dedicated
    fresh.bump_epoch()                 # full rebuild
    assert np.array_equal(mask_after, fresh._shape_fit_mask(shape))
    assert np.array_equal(cnt_after, fresh._free_cnt)

    rt.release(12345)                  # partial again, the other way
    fresh2 = ReconfigTorus(num_xpus, cube_n)
    fresh2.occ[:] = rt.occ
    fresh2.bump_epoch()
    assert np.array_equal(rt._shape_fit_mask(shape),
                          fresh2._shape_fit_mask(shape))
    assert np.array_equal(rt._free_cnt, fresh2._free_cnt)
    rt.check_invariants()


def test_plan_table_is_prefix_sorted():
    """Fold tables visit offsets best-prefix-first with the offset
    product index as the stable tiebreak."""
    for dims in [(8, 4, 4), (18, 1, 1), (4, 8, 2), (3, 3, 3)]:
        for f in enumerate_folds(JobShape(dims), max_dim=64):
            tab = fold_plan_table(f, 4, 64)
            if tab is None:
                continue
            keys = list(zip(tab.nbroken.tolist(), tab.ncubes.tolist(),
                            tab.links.tolist()))
            assert keys == sorted(keys)


# ------------------------------------------------- fitmask multi-query
def test_block_sums_from_ii_multi_matches_single():
    rng = np.random.default_rng(5)
    occ = rng.uniform(size=(9, 4, 4, 4)) < 0.4
    ii = fitmask.batched_integral_image(occ)
    locals_ = []
    for _ in range(20):
        lo = rng.integers(0, 4, size=3)
        hi = [int(rng.integers(int(loc) + 1, 5)) for loc in lo]
        locals_.append(tuple((int(loc), h) for loc, h in zip(lo, hi)))
    multi = fitmask.block_sums_from_ii_multi(ii, locals_)
    assert multi.shape == (len(locals_), occ.shape[0])
    for k, loc in enumerate(locals_):
        assert np.array_equal(multi[k], fitmask.block_sums_from_ii(ii, loc))
    free = fitmask.block_free_from_ii_multi(ii, locals_)
    assert np.array_equal(free, multi == 0)


def test_host_free_counts_helper():
    rng = np.random.default_rng(6)
    occ = rng.uniform(size=(5, 3, 3, 3)) < 0.5
    ref = np.array([(~occ[i]).sum() for i in range(5)])
    assert np.array_equal(fitmask.free_counts(occ), ref)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
