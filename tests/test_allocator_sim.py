"""Allocator policies + simulator behaviour + trace generator."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.allocator import (FirstFitPolicy, FoldingPolicy,
                                  RFoldPolicy, ReconfigPolicy, make_policy)
from repro.core.geometry import JobShape
from repro.sim.job import Job
from repro.sim.metrics import aggregate, time_weighted_utilization
from repro.sim.simulator import Simulator
from repro.traces.generator import TraceConfig, generate_trace

# ---------------------------------------------------------------- policies
def test_firstfit_rejects_oversized_dim():
    ff = FirstFitPolicy((16, 16, 16))
    assert ff.try_place(1, JobShape((4, 4, 32))) is None
    assert not ff.can_ever_place(JobShape((4, 4, 32)))
    assert ff.can_ever_place(JobShape((16, 16, 16)))


def test_folding_beats_firstfit_on_long_1d():
    fo = FoldingPolicy((16, 16, 16))
    assert fo.can_ever_place(JobShape((18, 1, 1)))
    p = fo.try_place(1, JobShape((18, 1, 1)))
    assert p is not None and p.rings_intact


def test_reconfig_places_paper_4x4x32():
    rc = ReconfigPolicy(4096, 4)
    p = rc.try_place(1, JobShape((4, 4, 32)))
    assert p is not None
    assert p.meta["num_cubes"] == 8
    assert p.meta["wrap"] == (True, True, True)


def test_rfold_prefers_fewest_cubes():
    rf = RFoldPolicy(4096, 4)
    p = rf.try_place(1, JobShape((18, 1, 1)))
    assert p is not None
    assert p.meta["num_cubes"] == 1          # folded into one cube
    assert not p.broken_rings


def test_rfold_beats_reconfig_on_cube_count():
    rc, rf = ReconfigPolicy(4096, 4), RFoldPolicy(4096, 4)
    shape = JobShape((4, 8, 2))              # paper: foldable to 4x4x4
    pc = rc.try_place(1, shape)
    pf = rf.try_place(1, shape)
    assert pc.meta["num_cubes"] == 2
    assert pf.meta["num_cubes"] == 1


def test_release_restores_capacity():
    rf = RFoldPolicy(512, 4)
    p1 = rf.try_place(1, JobShape((8, 8, 8)))
    assert p1 is not None
    assert rf.try_place(2, JobShape((8, 8, 8))) is None
    rf.release(1)
    assert rf.try_place(2, JobShape((8, 8, 8))) is not None


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 100_000))
def test_policies_never_double_book(seed):
    rng = np.random.default_rng(seed)
    pol = RFoldPolicy(512, 4)
    live = []
    for jid in range(25):
        if live and rng.uniform() < 0.35:
            pol.release(live.pop(rng.integers(len(live))))
        dims = tuple(int(rng.integers(1, 10)) for _ in range(3))
        if pol.try_place(jid, JobShape(dims)) is not None:
            live.append(jid)
        pol.cluster.check_invariants()


def test_static_policies_never_double_book():
    rng = np.random.default_rng(0)
    pol = FoldingPolicy((8, 8, 8))
    live = []
    for jid in range(40):
        if live and rng.uniform() < 0.4:
            pol.release(live.pop(rng.integers(len(live))))
        dims = tuple(int(rng.integers(1, 9)) for _ in range(3))
        if pol.try_place(jid, JobShape(dims)) is not None:
            live.append(jid)
        pol.torus.check_invariants()


# --------------------------------------------------------------- simulator
def _jobs(specs):
    return [Job(job_id=i, arrival=a, duration=d, shape=JobShape(s))
            for i, (a, d, s) in enumerate(specs)]


def test_fifo_head_of_line_blocking():
    # job0 fills the cluster; job1 (too big to coexist) blocks job2 even
    # though job2 would fit.
    jobs = _jobs([(0.0, 100.0, (8, 8, 8)),
                  (1.0, 10.0, (8, 8, 8)),
                  (2.0, 10.0, (2, 2, 2))])
    pol = RFoldPolicy(512, 4)
    res = Simulator(pol, jobs).run()
    j0, j1, j2 = res.jobs
    assert j0.start == 0.0
    assert j1.start == pytest.approx(100.0)
    assert j2.start >= j1.start                   # blocked behind head
    assert res.jcr == 1.0


def test_incompatible_shape_dropped_not_blocking():
    jobs = _jobs([(0.0, 50.0, (4, 4, 32)),       # impossible in 16^3 static
                  (1.0, 5.0, (2, 2, 2))])
    pol = FirstFitPolicy((16, 16, 16))
    res = Simulator(pol, jobs).run()
    assert res.jobs[0].dropped
    assert res.jobs[1].start == pytest.approx(1.0)
    assert res.jcr == 0.5


def test_broken_ring_slowdown_applied():
    jobs = _jobs([(0.0, 100.0, (6, 1, 1))])      # 6-ring, no wrap in 8^3
    pol = FirstFitPolicy((8, 8, 8))
    res = Simulator(pol, jobs, broken_ring_slowdown=1.17).run()
    assert res.jobs[0].slowdown == pytest.approx(1.17)
    assert res.jobs[0].finish == pytest.approx(117.0)
    # folding closes the ring -> no slowdown
    pol2 = FoldingPolicy((8, 8, 8))
    res2 = Simulator(pol2, _jobs([(0.0, 100.0, (6, 1, 1))])).run()
    assert res2.jobs[0].slowdown == 1.0


def test_utilization_accounting():
    jobs = _jobs([(0.0, 10.0, (8, 8, 8))])       # fills 512-XPU cluster
    pol = RFoldPolicy(512, 4)
    res = Simulator(pol, jobs).run()
    util = time_weighted_utilization(res)
    assert util["mean"] == pytest.approx(1.0)


def test_metrics_aggregate():
    s = aggregate([{"a": 1.0, "b": 2.0}, {"a": 3.0, "b": 4.0}])
    assert s == {"a": 2.0, "b": 3.0}


# ------------------------------------------------------------------ traces
def test_trace_shapes_follow_paper_rule():
    cfg = TraceConfig(num_jobs=400, seed=0)
    jobs = generate_trace(cfg)
    small = [j for j in jobs if j.size <= 256]
    large = [j for j in jobs if j.size > 256]
    assert all(j.shape.ndim <= 2 for j in small)
    assert all(j.shape.ndim >= 2 for j in large)
    assert all(1 <= j.size <= 4096 + 64 for j in jobs)
    # arrivals sorted, durations positive
    arr = [j.arrival for j in jobs]
    assert arr == sorted(arr)
    assert all(j.duration > 0 for j in jobs)


def test_trace_shapes_are_cube4_decomposable():
    cfg = TraceConfig(num_jobs=300, seed=1)
    for j in generate_trace(cfg):
        cubes = 1
        for d in j.shape.dims:
            cubes *= -(-d // 4)
        assert cubes <= 64


def test_trace_deterministic_by_seed():
    a = generate_trace(TraceConfig(num_jobs=50, seed=5))
    b = generate_trace(TraceConfig(num_jobs=50, seed=5))
    assert [(j.arrival, j.shape.dims) for j in a] == \
           [(j.arrival, j.shape.dims) for j in b]


def test_paper_jcr_ordering_holds_on_small_trace():
    """Weak-form Table 1: FirstFit < Folding < RFold(4^3) = 100%."""
    cfg = TraceConfig(num_jobs=120, seed=11)
    jcr = {}
    for name, kw in [("firstfit", dict(dims=(16, 16, 16))),
                     ("folding", dict(dims=(16, 16, 16))),
                     ("rfold", dict(num_xpus=4096, cube_n=4))]:
        pol = make_policy(name, **kw)
        jcr[name] = Simulator(pol, generate_trace(cfg)).run().jcr
    assert jcr["firstfit"] < jcr["folding"] < 1.0
    assert jcr["rfold"] == 1.0


# ----------------------------------------------------- beyond-paper
def test_backfill_unblocks_small_jobs():
    from repro.core.allocator import RFoldPolicy
    jobs = _jobs([(0.0, 100.0, (8, 8, 4)),   # half the cluster
                  (1.0, 10.0, (8, 8, 8)),     # cannot coexist: blocks FIFO
                  (2.0, 10.0, (2, 2, 2))])
    res = Simulator(RFoldPolicy(512, 4), jobs, backfill=True).run()
    j2 = res.jobs[2]
    assert j2.start == pytest.approx(2.0)     # backfilled immediately
    # FIFO baseline: j2 waits behind the blocked head
    res2 = Simulator(RFoldPolicy(512, 4),
                     _jobs([(0.0, 100.0, (8, 8, 4)),
                            (1.0, 10.0, (8, 8, 8)),
                            (2.0, 10.0, (2, 2, 2))]), backfill=False).run()
    assert res2.jobs[2].start > 2.0


def test_best_effort_scatter_placement():
    from repro.core.allocator import RFoldBestEffortPolicy
    pol = RFoldBestEffortPolicy(64, 2, scatter_slowdown=1.5)
    # fragment the cluster so no contiguous/folded 3x3x3 placement
    # exists: occupy every cube's corner cell via a scatter allocation
    pol.cluster.commit_scatter(99, [(cid, 0, 0, 0)
                                    for cid in range(pol.cluster.num_cubes)])
    p = pol.try_place(1, JobShape((3, 3, 3)))
    assert p is not None
    assert p.meta.get("kind") == "scatter"
    assert p.meta["slowdown_factor"] == 1.5
    pol.cluster.check_invariants()
    pol.release(1)
    assert pol.busy_xpus == pol.cluster.num_cubes  # only poison remains
