"""Launch layer: mesh construction, sharding specs, collective-byte
parser, and a subprocess mini dry-run (8 placeholder devices — the full
512-device sweep runs via `python -m repro.launch.dryrun --all`)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.dryrun import parse_collective_bytes
from repro.launch.mesh import allocation_mesh_shape, mesh_from_allocation
from repro.parallel.sharding import (DEFAULT_RULES, param_logical_axes,
                                     rules_for, safe_spec, spec_for)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_parse_collective_bytes():
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[64]{0} all-gather(%y), dimensions={0}
  %nothing = f32[8]{0} add(%a, %b)
  %cp = (s32[4]{0}, s32[4]{0}) collective-permute(%p, %q)
"""
    out = parse_collective_bytes(hlo)
    assert out["all-reduce"]["bytes"] == 128 * 256 * 4
    assert out["all-gather"]["bytes"] == 64 * 2
    assert out["collective-permute"]["bytes"] == 32
    assert out["total_count"] == 3


def test_rules_adapt_to_mesh_axes():
    mesh = jax.make_mesh((1,), ("data",))
    rules = rules_for(mesh)
    assert rules["heads"] is None        # no model axis
    assert rules["batch"] == "data"      # no pod axis
    assert spec_for(("batch", "seq"), rules) == P("data", None)


def test_safe_spec_divisibility():
    mesh = jax.make_mesh((1,), ("data",))
    rules = {"batch": "data", "heads": "data"}
    # dim 7 not divisible by 1? axis size 1 -> dropped (sz>1 required)
    assert safe_spec((7, 3), ("batch", None), mesh, rules) == P(None, None)


def test_param_logical_axes_moe_no_duplicate():
    params = {"moe": {"w_gate": jnp.zeros((160, 64, 32)),
                      "w_down": jnp.zeros((160, 32, 64)),
                      "router": jnp.zeros((64, 160))},
              "attn": {"w_q": jnp.zeros((64, 64))}}
    axes = param_logical_axes(params, n_expert_hint=160)
    def is_leaf(x):
        return isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
    flat = jax.tree_util.tree_leaves(axes, is_leaf=is_leaf)
    for a in flat:
        resolved = [DEFAULT_RULES.get(n) if n else None for n in a]
        named = [r for r in resolved if isinstance(r, str)]
        assert len(named) == len(set(named)), a


def test_mesh_from_allocation_order():
    coords = [(0, 0, i) for i in range(len(jax.devices()))]
    n = len(coords)
    mesh = mesh_from_allocation(coords, (n, 1), ("data", "model"))
    assert mesh.shape == {"data": n, "model": 1}


def test_allocation_mesh_shape():
    d, m = allocation_mesh_shape(16)
    assert d * m == 16
    d, m = allocation_mesh_shape(24, prefer_model=6)
    assert (d, m) == (4, 6)


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    """Lower+compile a reduced config on an 8-device host mesh in a
    clean subprocess (dryrun.py owns XLA_FLAGS; tests must not)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
from repro.configs import get_config, smoke_variant
from repro.configs.shapes import InputShape, batch_specs
from repro.models import model as lm
from repro.parallel.sharding import (logical_rules, param_shardings,
                                     rules_for, batch_specs_sharding)
from repro.train.optim import OptimConfig, init_opt_state
from repro.train.train_step import train_step

cfg = smoke_variant(get_config("llama3-8b"))
mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = rules_for(mesh)
shape = InputShape("mini", 64, 8, "train")
params = jax.eval_shape(lambda: lm.init_model(cfg, jax.random.PRNGKey(0)))
ps = param_shardings(params, mesh, rules)
opt = jax.eval_shape(init_opt_state, params)
os_ = {"mu": ps, "nu": ps,
       "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())}
bs = batch_specs(cfg, shape)
bsh = batch_specs_sharding(bs, mesh, rules)
oc = OptimConfig()

def fn(p, o, b):
    with logical_rules(rules):
        np_, no, m = train_step(cfg, oc, p, o, b)
    return np_, no, m["loss"]

with mesh:
    lowered = jax.jit(fn, in_shardings=(ps, os_, bsh),
                      out_shardings=(ps, os_, None)).lower(params, opt, bs)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
if isinstance(cost, (list, tuple)):
    cost = cost[0] if cost else {}
print(json.dumps({"flops": cost.get("flops", -1),
                  "devices": len(jax.devices())}))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    assert out["flops"] > 0


@pytest.mark.slow
def test_mini_dryrun_decode_subprocess():
    """serve_step lowers under a small mesh with sharded KV caches."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
from repro.configs import get_config, smoke_variant
from repro.configs.shapes import InputShape, batch_specs
from repro.models import model as lm
from repro.parallel.sharding import (logical_rules, param_shardings,
                                     rules_for, batch_specs_sharding,
                                     decode_state_specs)
from repro.serve import engine

cfg = smoke_variant(get_config("zamba2-1.2b"))
mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = rules_for(mesh)
shape = InputShape("mini_dec", 64, 8, "decode")
params = jax.eval_shape(lambda: lm.init_model(cfg, jax.random.PRNGKey(0)))
ps = param_shardings(params, mesh, rules)
state = jax.eval_shape(lambda: engine.init_state(cfg, 8, 64))
ss = decode_state_specs(state, mesh, rules)
bs = batch_specs(cfg, shape)
bsh = batch_specs_sharding(bs, mesh, rules)

def fn(p, s, b):
    with logical_rules(rules):
        return engine.serve_step(cfg, p, s, b)

with mesh:
    compiled = jax.jit(fn, in_shardings=(ps, ss, bsh),
                       out_shardings=(None, ss)).lower(
        params, state, bs).compile()
print(json.dumps({"ok": True}))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
