"""Per-architecture smoke tests (deliverable f): a REDUCED variant of
each assigned architecture runs one forward + one train step + a few
decode steps on CPU; output shapes and finiteness asserted.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, get_config, smoke_variant
from repro.configs.registry import ARCH_IDS
from repro.configs.shapes import concrete_batch, smoke_shape
from repro.models import model as lm
from repro.serve import engine
from repro.train.optim import OptimConfig, init_opt_state
from repro.train.train_step import train_step

ARCHS = ARCH_IDS


@pytest.fixture(scope="module")
def smoke_models():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = smoke_variant(get_config(name))
            params = lm.init_model(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]

    return get


def test_all_archs_registered():
    assert sorted(all_configs()) == sorted(ARCHS)
    assert len(ARCHS) == 10


@pytest.mark.parametrize("name", ARCHS)
def test_full_config_matches_assignment(name):
    cfg = get_config(name)
    expect = {
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
    }[name]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect
    assert cfg.source  # provenance citation present


def test_assignment_special_features():
    assert get_config("deepseek-v2-236b").use_mla
    assert get_config("deepseek-v2-236b").kv_lora_rank == 512
    assert get_config("deepseek-v2-236b").n_experts == 160
    assert get_config("deepseek-v2-236b").moe_top_k == 6
    assert get_config("deepseek-v2-236b").n_shared_experts == 2
    assert get_config("llama4-scout-17b-a16e").n_experts == 16
    assert get_config("llama4-scout-17b-a16e").moe_top_k == 1
    assert get_config("qwen1.5-110b").qkv_bias
    assert get_config("olmo-1b").norm_type == "nonparametric_ln"
    assert get_config("zamba2-1.2b").ssm_state == 64
    assert get_config("musicgen-medium").n_codebooks == 4
    assert get_config("xlstm-1.3b").use_xlstm
    assert get_config("qwen2-vl-7b").pos_type == "mrope"
    assert get_config("qwen2-vl-7b").n_kv_heads == 4


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_forward_shapes_and_finite(smoke_models, name):
    cfg, params = smoke_models(name)
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    batch = concrete_batch(cfg, smoke_shape("train", 32, 2))
    logits, aux = lm.forward(cfg, params, batch)
    if cfg.arch_type == "audio":
        assert logits.shape == (2, 32, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (2, 32, cfg.vocab_size)
    assert jnp.isfinite(logits).all()


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_train_step(smoke_models, name):
    cfg, params = smoke_models(name)
    batch = concrete_batch(cfg, smoke_shape("train", 32, 2))
    opt_cfg = OptimConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt_state = init_opt_state(params)
    p1, o1, metrics = jax.jit(
        lambda p, o, b: train_step(cfg, opt_cfg, p, o, b))(
        params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert metrics["grad_norm"] > 0
    # params actually changed
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), params, p1)
    assert max(jax.tree_util.tree_leaves(diffs)) > 0
    # second step decreases loss on the same batch (sanity)
    _, _, m2 = jax.jit(
        lambda p, o, b: train_step(cfg, opt_cfg, p, o, b))(p1, o1, batch)
    assert jnp.isfinite(m2["loss"])


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_decode_steps(smoke_models, name):
    cfg, params = smoke_models(name)
    b, steps = 2, 4
    window = 16
    state = engine.init_state(cfg, b, window)
    for t in range(steps):
        if cfg.arch_type == "audio":
            toks = jnp.full((b, cfg.n_codebooks, 1), t % cfg.vocab_size,
                            jnp.int32)
        else:
            toks = jnp.full((b, 1), t % cfg.vocab_size, jnp.int32)
        pos = jnp.full((b, 1), t, jnp.int32)
        batch = {"tokens": toks, "positions": pos}
        if cfg.pos_type == "mrope":
            batch["positions"] = jnp.broadcast_to(pos[:, :, None],
                                                  (b, 1, 3))
        logits, state = engine.serve_step(cfg, params, state, batch)
        assert jnp.isfinite(logits).all()
    if cfg.arch_type == "audio":
        assert logits.shape == (b, 1, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (b, 1, cfg.vocab_size)


@pytest.mark.parametrize("name", ["llama3-8b", "xlstm-1.3b", "zamba2-1.2b",
                                  "deepseek-v2-236b"])
def test_decode_matches_forward(smoke_models, name):
    """Token-by-token decode logits must match the parallel forward —
    the strongest cross-check of cache/state correctness."""
    cfg, params = smoke_models(name)
    cfg = cfg.replace(sliding_window=0, dtype="float32")
    if cfg.n_experts:
        # capacity dropping differs between a 1-token decode batch and a
        # full-sequence forward; give slack so routing is drop-free and
        # the decode == forward invariant is exact.
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    b, s = 2, 8
    rng = np.random.default_rng(0)
    toks = jnp.array(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    full_logits, _ = lm.forward(cfg, params, {"tokens": toks})

    state = engine.init_state(cfg, b, window=s)
    outs = []
    for t in range(s):
        batch = {"tokens": toks[:, t:t + 1],
                 "positions": jnp.full((b, 1), t, jnp.int32)}
        lg, state = engine.serve_step(cfg, params, state, batch)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_vlm_patch_embedding_stub():
    cfg, params_key = smoke_variant(get_config("qwen2-vl-7b")), \
        jax.random.PRNGKey(1)
    params = lm.init_model(cfg, params_key)
    b, s = 2, 16
    rng = np.random.default_rng(0)
    toks = jnp.array(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    pe = jnp.array(rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
    mask = jnp.zeros((b, s), bool).at[:, :4].set(True)  # 4 image patches
    pos3 = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :, None],
                            (b, s, 3))
    logits, _ = lm.forward(cfg, params, {
        "tokens": toks, "patch_embeds": pe, "patch_mask": mask,
        "positions": pos3})
    assert jnp.isfinite(logits).all()


def test_audio_embeds_stub():
    cfg = smoke_variant(get_config("musicgen-medium"))
    params = lm.init_model(cfg, jax.random.PRNGKey(2))
    b, s = 2, 16
    rng = np.random.default_rng(0)
    emb = jnp.array(rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
    logits, _ = lm.forward(cfg, params, {"embeds": emb})
    assert logits.shape == (b, s, cfg.n_codebooks, cfg.vocab_size)


def test_greedy_decode_runs():
    cfg, _ = smoke_variant(get_config("olmo-1b")), None
    params = lm.init_model(cfg, jax.random.PRNGKey(3))
    prompt = jnp.array([[1, 2, 3, 4]], jnp.int32)
    out = engine.greedy_decode(cfg, params, prompt, steps=3)
    assert out.shape == (1, 7)


def test_mla_absorbed_decode_matches_naive(smoke_models):
    """Weight-absorbed MLA decode is mathematically identical to the
    expand-k/v path (beyond-paper perf optimization)."""
    cfg, params = smoke_models("deepseek-v2-236b")
    cfg = cfg.replace(sliding_window=0, dtype="float32",
                      capacity_factor=16.0)
    b, s = 2, 6
    rng = np.random.default_rng(3)
    toks = jnp.array(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)

    def run(c):
        state = engine.init_state(c, b, window=s)
        outs = []
        for t in range(s):
            batch = {"tokens": toks[:, t:t + 1],
                     "positions": jnp.full((b, 1), t, jnp.int32)}
            lg, state = engine.serve_step(c, params, state, batch)
            outs.append(lg[:, 0])
        return jnp.stack(outs, 1)

    naive = run(cfg.replace(mla_absorb=False))
    absorbed = run(cfg.replace(mla_absorb=True))
    np.testing.assert_allclose(np.asarray(absorbed), np.asarray(naive),
                               rtol=2e-4, atol=2e-4)
