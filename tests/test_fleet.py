"""Fleet simulation layer tests (repro.sim.fleet + the eval runner's
two-level pool): broker coalescing and bit-exactness, byte-identical
fleet records vs the sequential single-sim path, worker-side
checkpointing, and chunking/auto-sizing."""
import threading

import numpy as np
import pytest

from repro.eval import EvalRunner, make_tasks
from repro.eval.runner import (iter_checkpoints, make_fleet_chunks,
                               run_fleet_tasks, task_grid_bucket)
from repro.kernels.fitmask import ops
from repro.sim.fleet import Fleet, QueryBroker, install_mask_client

# Small matrix covering both cluster models and two grid cell shapes.
CONFIGS = [
    ("RFold (4^3)", "rfold", dict(num_xpus=512, cube_n=4)),
    ("Reconfig (4^3)", "reconfig", dict(num_xpus=512, cube_n=4)),
    ("Folding (8^3)", "folding", dict(dims=(8, 8, 8))),
    ("FirstFit (8^3)", "firstfit", dict(dims=(8, 8, 8))),
]


def _tasks(runs=2, num_jobs=25):
    return make_tasks(CONFIGS, runs=runs, num_jobs=num_jobs, load=1.5,
                      seed0=100)


def _strip(records):
    return [{k: v for k, v in r.items() if k != "sim_s"} for r in records]


def _occ(rng, b, cell):
    return rng.random((b,) + cell) < 0.4


# ------------------------------------------------------------- broker
def test_solo_broker_matches_inline_engine():
    """An unregistered broker answers immediately and bit-exactly."""
    rng = np.random.default_rng(0)
    occ = _occ(rng, 3, (6, 6, 6))
    boxes = ((2, 2, 1), (3, 1, 2), (6, 6, 6))
    broker = QueryBroker("numpy")
    ref = np.asarray(ops.get_engine("numpy").multibox(occ, boxes))
    np.testing.assert_array_equal(broker.multibox(occ, boxes), ref)
    np.testing.assert_array_equal(
        broker.free_counts(occ),
        np.asarray(ops.get_engine("numpy").free_counts(occ)))
    assert broker.stats.engine_calls == 2
    assert broker.stats.batched_calls == 0


def test_broker_coalesces_and_splits_exactly():
    """Three concurrent requests over the same cell shape: one engine
    call, every requester gets its own grids and its own boxes back,
    in its own order."""
    rng = np.random.default_rng(1)
    cell = (5, 5, 5)
    reqs = [(_occ(rng, b, cell), boxes) for b, boxes in
            [(1, ((2, 2, 2), (1, 1, 4))),
             (4, ((1, 1, 4), (3, 3, 1))),
             (2, ((5, 5, 5),))]]
    broker = QueryBroker("numpy")
    results = [None] * len(reqs)

    def worker(i):
        occ, boxes = reqs[i]
        results[i] = broker.multibox(occ, boxes)

    for _ in reqs:
        broker.register()
    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(len(reqs))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for (occ, boxes), out in zip(reqs, results):
        ref = np.asarray(ops.get_engine("numpy").multibox(occ, boxes))
        np.testing.assert_array_equal(out, ref)
    assert broker.stats.engine_calls == 1          # one coalesced call
    assert broker.stats.batched_calls == 1
    assert broker.stats.max_coalesced == 3
    assert broker.stats.max_grids == 7             # 1 + 4 + 2 stacked


def test_broker_buckets_by_cell_shape():
    """Different grid cell shapes cannot share a pass: two engine
    calls, both answered correctly."""
    rng = np.random.default_rng(2)
    a, b = _occ(rng, 2, (4, 4, 4)), _occ(rng, 1, (8, 8, 8))
    broker = QueryBroker("numpy")
    results = {}

    def worker(key, occ, boxes):
        results[key] = broker.multibox(occ, boxes)

    broker.register()
    broker.register()
    ts = [threading.Thread(target=worker, args=("a", a, ((2, 2, 2),))),
          threading.Thread(target=worker, args=("b", b, ((3, 3, 3),)))]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    np.testing.assert_array_equal(
        results["a"],
        np.asarray(ops.get_engine("numpy").multibox(a, ((2, 2, 2),))))
    np.testing.assert_array_equal(
        results["b"],
        np.asarray(ops.get_engine("numpy").multibox(b, ((3, 3, 3),))))
    assert broker.stats.engine_calls == 2
    assert broker.stats.batched_calls == 0


def test_deactivate_triggers_pending_flush():
    """A simulator finishing while its peer waits must flush the
    peer's round — nobody else will."""
    broker = QueryBroker("numpy")
    broker.register()
    broker.register()
    occ = np.zeros((1, 4, 4, 4), dtype=bool)
    out = {}

    def waiter():
        out["res"] = broker.free_counts(occ)

    t = threading.Thread(target=waiter)
    t.start()
    while not broker.stats.requests:   # parked, waiting for peer
        pass
    broker.deactivate()                # peer finishes without querying
    t.join(timeout=5)
    assert not t.is_alive()
    assert out["res"].tolist() == [64]


def test_broker_propagates_engine_errors():
    class Boom:
        def multibox(self, occ, boxes):
            raise RuntimeError("engine down")

        def free_counts(self, occ):
            raise RuntimeError("engine down")

    broker = QueryBroker(Boom())
    with pytest.raises(RuntimeError, match="engine down"):
        broker.multibox(np.zeros((1, 4, 4, 4), dtype=bool), ((1, 1, 1),))


def test_broker_rejects_unbatched_grids():
    with pytest.raises(ValueError, match=r"\(B, X, Y, Z\)"):
        QueryBroker("numpy").free_counts(np.zeros((4, 4, 4), dtype=bool))


def test_fleet_surfaces_unit_exception():
    def bad(broker):
        raise ValueError("sim exploded")

    def good(broker):
        return int(broker.free_counts(
            np.zeros((1, 2, 2, 2), dtype=bool))[0])

    with pytest.raises(ValueError, match="sim exploded"):
        Fleet("numpy").run([bad, good])


def test_install_mask_client_requires_cluster_model():
    with pytest.raises(TypeError):
        install_mask_client(object(), QueryBroker("numpy"))


# ---------------------------------------------------- fleet-of-sims
def test_fleet_records_byte_identical_to_sequential():
    """The tentpole parity contract: fleets produce the same records
    (minus timing) as the per-task oracle path, for both cluster
    models, while genuinely batching engine calls."""
    tasks = _tasks()
    seq = EvalRunner(workers=0).run(tasks)
    runner = EvalRunner(workers=0, fleet_size=4)
    fl = runner.run(tasks)
    assert _strip(seq) == _strip(fl)
    broker = runner.last_stats["fleet"]["broker"]
    assert broker["batched_calls"] > 0
    assert broker["mean_grids_per_call"] > 1


def test_fleet_pool_records_identical(tmp_path):
    """Two-level pool (processes x fleets) returns the same records
    and writes every checkpoint worker-side."""
    tasks = _tasks(runs=2)
    seq = EvalRunner(workers=0).run(tasks)
    ckpt = str(tmp_path / "ckpt")
    runner = EvalRunner(checkpoint_dir=ckpt, workers=2, fleet_size=2)
    fl = runner.run(tasks)
    assert _strip(seq) == _strip(fl)
    assert len(list(iter_checkpoints(ckpt))) == len(tasks)
    # resume reuses everything the fleet workers checkpointed
    resumed = EvalRunner(checkpoint_dir=ckpt, workers=2, fleet_size=2)
    resumed.run(tasks)
    assert resumed.last_stats["reused_from_checkpoint"] == len(tasks)


def test_run_fleet_tasks_engine_override_is_bit_exact():
    """The broker's engine choice cannot change records (engines are
    parity-tested); only where masks get computed differs."""
    tasks = _tasks(runs=1, num_jobs=15)
    base, _ = run_fleet_tasks(tasks)
    ref, stats = run_fleet_tasks(tasks, engine="ref")
    assert _strip(base) == _strip(ref)
    assert stats["engine_calls"] > 0


# ------------------------------------------------- chunking / sizing
def test_task_grid_bucket_defaults_mirror_make_policy():
    tasks = _tasks(runs=1)
    buckets = {t.label: task_grid_bucket(t) for t in tasks}
    assert buckets["RFold (4^3)"] == ("cube", 4)
    assert buckets["Folding (8^3)"] == ("static", (8, 8, 8))
    t = make_tasks([("x", "folding", {})], runs=1, num_jobs=5, load=1.0,
                   seed0=0)[0]
    assert task_grid_bucket(t) == ("static", (16, 16, 16))


def test_make_fleet_chunks_groups_buckets_and_caps_size():
    tasks = _tasks(runs=3)             # 6 cube tasks + 6 static tasks
    chunks = make_fleet_chunks(tasks, list(range(len(tasks))), 4)
    assert sorted(i for c in chunks for i in c) == list(range(len(tasks)))
    for chunk in chunks:
        assert len(chunk) <= 4
        assert len({task_grid_bucket(tasks[i]) for i in chunk}) == 1


def test_auto_fleet_size_scales_with_pending_and_workers():
    r = EvalRunner(workers=2, fleet_size="auto", fleet_engine="jax")
    assert r._resolve_fleet_size(24) == 3     # ceil(24 / (4*2))
    assert r._resolve_fleet_size(800) == 8    # capped
    assert r._resolve_fleet_size(2) == 2      # floor
    assert EvalRunner(workers=2)._resolve_fleet_size(24) is None
    assert EvalRunner(workers=2,
                      fleet_size=6)._resolve_fleet_size(24) == 6


def test_auto_fleet_size_keeps_per_task_path_on_numpy_host():
    """auto is engine-aware: the host numpy path stays per-task (it
    is faster there — see BENCH_fleet.json's parity section); batched
    engines fleet."""
    assert EvalRunner(workers=2,
                      fleet_size="auto")._resolve_fleet_size(24) is None
    assert EvalRunner(workers=2, fleet_size="auto",
                      fleet_engine="numpy")._resolve_fleet_size(24) is None
    assert EvalRunner(workers=2, fleet_size="auto",
                      fleet_engine="pallas")._resolve_fleet_size(24) == 3


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
