"""Fleet simulation layer tests (repro.sim.fleet + the eval runner's
two-level pool): broker coalescing and bit-exactness, continuous
(quorum/timeout) flush scheduling, byte-identical fleet records vs the
sequential single-sim path, worker-side checkpointing, and
chunking/auto-sizing."""
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.eval import EvalRunner, make_tasks
from repro.eval.runner import (iter_checkpoints, make_fleet_chunks,
                               run_fleet_tasks, task_grid_bucket)
from repro.kernels.fitmask import ops
from repro.sim.fleet import Fleet, QueryBroker, install_mask_client

# Small matrix covering both cluster models and two grid cell shapes.
CONFIGS = [
    ("RFold (4^3)", "rfold", dict(num_xpus=512, cube_n=4)),
    ("Reconfig (4^3)", "reconfig", dict(num_xpus=512, cube_n=4)),
    ("Folding (8^3)", "folding", dict(dims=(8, 8, 8))),
    ("FirstFit (8^3)", "firstfit", dict(dims=(8, 8, 8))),
]


def _tasks(runs=2, num_jobs=25):
    return make_tasks(CONFIGS, runs=runs, num_jobs=num_jobs, load=1.5,
                      seed0=100)


def _strip(records):
    return [{k: v for k, v in r.items() if k != "sim_s"} for r in records]


def _occ(rng, b, cell):
    return rng.random((b,) + cell) < 0.4


# ------------------------------------------------------------- broker
def test_solo_broker_matches_inline_engine():
    """An unregistered broker answers immediately and bit-exactly."""
    rng = np.random.default_rng(0)
    occ = _occ(rng, 3, (6, 6, 6))
    boxes = ((2, 2, 1), (3, 1, 2), (6, 6, 6))
    broker = QueryBroker("numpy")
    ref = np.asarray(ops.get_engine("numpy").multibox(occ, boxes))
    np.testing.assert_array_equal(broker.multibox(occ, boxes), ref)
    np.testing.assert_array_equal(
        broker.free_counts(occ),
        np.asarray(ops.get_engine("numpy").free_counts(occ)))
    assert broker.stats.engine_calls == 2
    assert broker.stats.batched_calls == 0


def test_broker_coalesces_and_splits_exactly():
    """Three concurrent requests over the same cell shape: one engine
    call, every requester gets its own grids and its own boxes back,
    in its own order."""
    rng = np.random.default_rng(1)
    cell = (5, 5, 5)
    reqs = [(_occ(rng, b, cell), boxes) for b, boxes in
            [(1, ((2, 2, 2), (1, 1, 4))),
             (4, ((1, 1, 4), (3, 3, 1))),
             (2, ((5, 5, 5),))]]
    broker = QueryBroker("numpy")
    results = [None] * len(reqs)

    def worker(i):
        occ, boxes = reqs[i]
        results[i] = broker.multibox(occ, boxes)

    for _ in reqs:
        broker.register()
    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(len(reqs))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for (occ, boxes), out in zip(reqs, results):
        ref = np.asarray(ops.get_engine("numpy").multibox(occ, boxes))
        np.testing.assert_array_equal(out, ref)
    assert broker.stats.engine_calls == 1          # one coalesced call
    assert broker.stats.batched_calls == 1
    assert broker.stats.max_coalesced == 3
    assert broker.stats.max_grids == 7             # 1 + 4 + 2 stacked


def test_broker_buckets_by_cell_shape():
    """Different grid cell shapes cannot share a pass: two engine
    calls, both answered correctly."""
    rng = np.random.default_rng(2)
    a, b = _occ(rng, 2, (4, 4, 4)), _occ(rng, 1, (8, 8, 8))
    broker = QueryBroker("numpy")
    results = {}

    def worker(key, occ, boxes):
        results[key] = broker.multibox(occ, boxes)

    broker.register()
    broker.register()
    ts = [threading.Thread(target=worker, args=("a", a, ((2, 2, 2),))),
          threading.Thread(target=worker, args=("b", b, ((3, 3, 3),)))]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    np.testing.assert_array_equal(
        results["a"],
        np.asarray(ops.get_engine("numpy").multibox(a, ((2, 2, 2),))))
    np.testing.assert_array_equal(
        results["b"],
        np.asarray(ops.get_engine("numpy").multibox(b, ((3, 3, 3),))))
    assert broker.stats.engine_calls == 2
    assert broker.stats.batched_calls == 0


def test_deactivate_triggers_pending_flush():
    """A simulator finishing while its peer waits must flush the
    peer's round — nobody else will (no quorum possible, no deadline
    set)."""
    broker = QueryBroker("numpy")
    broker.register()
    broker.register()
    occ = np.zeros((1, 4, 4, 4), dtype=bool)
    out = {}

    def waiter():
        out["res"] = broker.multibox(occ, ((2, 2, 2),))

    t = threading.Thread(target=waiter)
    t.start()
    while not broker.stats.requests:   # parked, waiting for peer
        pass
    broker.deactivate()                # peer finishes without querying
    t.join(timeout=5)
    assert not t.is_alive()
    assert int(np.count_nonzero(out["res"])) == 27   # 3^3 origins fit
    assert broker.stats.flush_all_parked == 1


def test_host_free_counts_answered_inline():
    """On the host engine a free-count query never parks: it is a
    cheap reduction, answered on the calling thread even while peers
    are live."""
    broker = QueryBroker("numpy")
    broker.register()
    broker.register()      # a peer that never queries
    occ = np.zeros((2, 4, 4, 4), dtype=bool)
    assert broker.free_counts(occ).tolist() == [64, 64]
    assert broker.stats.fc_inline == 1
    assert broker.stats.flushes == 0
    broker.deactivate()
    broker.deactivate()


def test_quorum_flush_does_not_wait_for_stragglers():
    """With a half-fleet quorum, two parked steppers out of four are
    answered without the other two ever querying."""
    broker = QueryBroker("numpy", quorum=0.5)
    for _ in range(4):
        broker.register()
    occ = np.zeros((1, 4, 4, 4), dtype=bool)
    outs = [None, None]

    def worker(i):
        outs[i] = broker.multibox(occ, ((1, 1, 1),))

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=5)
    assert not any(t.is_alive() for t in ts)
    for out in outs:
        assert int(np.count_nonzero(out)) == 64
    assert broker.stats.flush_quorum >= 1
    for _ in range(4):
        broker.deactivate()


def test_timeout_flush_bounds_the_wait():
    """A lone parked query in a live fleet is answered once the
    deadline elapses, not when the fleet drains."""
    broker = QueryBroker("numpy", timeout=0.005)
    broker.register()
    broker.register()      # peer that never queries
    occ = np.zeros((1, 4, 4, 4), dtype=bool)
    t0 = time.monotonic()
    out = broker.multibox(occ, ((4, 4, 4),))
    assert time.monotonic() - t0 < 2.0
    assert int(np.count_nonzero(out)) == 1
    assert broker.stats.flush_timeout == 1
    broker.deactivate()
    broker.deactivate()


def test_stale_pad_hint_recomputed_as_population_shrinks():
    """Satellite: the fleet-size pad hint is capped by the *live*
    population — a fleet of 8 down to 2 survivors pads flushes to 2,
    not 8."""
    broker = QueryBroker("jax", pad_b=True)
    broker.pad_hint = 8
    broker.register()
    broker.register()
    occ = np.zeros((1, 4, 4, 4), dtype=bool)
    outs = [None, None]

    def worker(i):
        outs[i] = broker.multibox(occ, ((2, 2, 2),))

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=30)
    assert not any(t.is_alive() for t in ts)
    # 2 real grids padded to the effective hint min(8, live=2) == 2:
    # no pad rows at all, where the stale hint would have added 6.
    assert broker.stats.grids == 2
    assert broker.stats.padded_grids == 0
    broker.deactivate()
    broker.deactivate()


def test_fc_content_cache_serves_free_counts_after_multibox():
    """Compiled engines: a multibox flush's fused free counts are
    remembered, so free_counts on the same occupancy never parks."""
    broker = QueryBroker("jax")
    rng = np.random.default_rng(8)
    occ = rng.random((2, 5, 5, 5)) < 0.4
    broker.multibox(occ, ((2, 2, 2),))
    flushes = broker.stats.flushes
    fc = broker.free_counts(occ)
    np.testing.assert_array_equal(
        fc, np.asarray(ops.get_engine("numpy").free_counts(occ)))
    assert broker.stats.fc_cache_hits == 1
    assert broker.stats.flushes == flushes   # answered without a round


def test_bucketed_k_padding_serves_exact_answers():
    """Compiled engines run per-bucket box tables padded to pow2
    capacity; answers are sliced back to each request's own boxes, in
    its own order."""
    broker = QueryBroker("jax", pad_b=True)
    rng = np.random.default_rng(9)
    occ = rng.random((1, 5, 5, 5)) < 0.4
    eng = ops.get_engine("numpy")
    b1 = ((3, 1, 2), (1, 1, 1), (2, 2, 2))
    out1 = broker.multibox(occ, b1)
    np.testing.assert_array_equal(np.asarray(out1) != 0,
                                  eng.multibox(occ, b1) != 0)
    # Second query re-uses the bucket's table; one new box appended.
    b2 = ((2, 2, 2), (4, 4, 4))
    out2 = broker.multibox(occ, b2)
    np.testing.assert_array_equal(np.asarray(out2) != 0,
                                  eng.multibox(occ, b2) != 0)
    assert broker.stats.k_slots >= broker.stats.k_needed > 0


def test_broker_propagates_engine_errors():
    class Boom:
        def multibox(self, occ, boxes):
            raise RuntimeError("engine down")

        def free_counts(self, occ):
            raise RuntimeError("engine down")

    broker = QueryBroker(Boom())
    with pytest.raises(RuntimeError, match="engine down"):
        broker.multibox(np.zeros((1, 4, 4, 4), dtype=bool), ((1, 1, 1),))


def test_broker_rejects_unbatched_grids():
    with pytest.raises(ValueError, match=r"\(B, X, Y, Z\)"):
        QueryBroker("numpy").free_counts(np.zeros((4, 4, 4), dtype=bool))


def test_fleet_surfaces_unit_exception():
    def bad(broker):
        raise ValueError("sim exploded")

    def good(broker):
        return int(broker.free_counts(
            np.zeros((1, 2, 2, 2), dtype=bool))[0])

    with pytest.raises(ValueError, match="sim exploded"):
        Fleet("numpy").run([bad, good])


def test_install_mask_client_requires_cluster_model():
    with pytest.raises(TypeError):
        install_mask_client(object(), QueryBroker("numpy"))


# ---------------------------------------------------- fleet-of-sims
def test_fleet_records_byte_identical_to_sequential():
    """The tentpole parity contract: fleets produce the same records
    (minus timing) as the per-task oracle path, for both cluster
    models, while genuinely batching engine calls."""
    tasks = _tasks()
    seq = EvalRunner(workers=0, fleet_size=0).run(tasks)
    runner = EvalRunner(workers=0, fleet_size=4)
    fl = runner.run(tasks)
    assert _strip(seq) == _strip(fl)
    broker = runner.last_stats["fleet"]["broker"]
    assert broker["batched_calls"] > 0
    assert broker["mean_grids_per_call"] > 1
    # the new scheduling/padding telemetry is aggregated too
    for key in ("flush_all_parked", "flush_quorum", "flush_timeout",
                "requeued", "b_pad_waste", "k_pad_waste", "fc_inline"):
        assert key in broker


def test_fleet_pool_records_identical(tmp_path):
    """Two-level pool (processes x fleets) returns the same records
    and writes every checkpoint worker-side."""
    tasks = _tasks(runs=2)
    seq = EvalRunner(workers=0, fleet_size=0).run(tasks)
    ckpt = str(tmp_path / "ckpt")
    runner = EvalRunner(checkpoint_dir=ckpt, workers=2, fleet_size=2)
    fl = runner.run(tasks)
    assert _strip(seq) == _strip(fl)
    assert len(list(iter_checkpoints(ckpt))) == len(tasks)
    # resume reuses everything the fleet workers checkpointed
    resumed = EvalRunner(checkpoint_dir=ckpt, workers=2, fleet_size=2)
    resumed.run(tasks)
    assert resumed.last_stats["reused_from_checkpoint"] == len(tasks)


def test_run_fleet_tasks_engine_override_is_bit_exact():
    """The broker's engine choice cannot change records (engines are
    parity-tested); only where masks get computed differs."""
    tasks = _tasks(runs=1, num_jobs=15)
    base, _ = run_fleet_tasks(tasks)
    ref, stats = run_fleet_tasks(tasks, engine="ref")
    assert _strip(base) == _strip(ref)
    assert stats["engine_calls"] > 0


# ------------------------------------- continuous-scheduling parity
def _random_query_plan(rng, cell, n_steppers):
    """Per-stepper deterministic query sequences over one cell shape:
    a mix of multibox (random B, random boxes) and free_counts."""
    plans = []
    for _ in range(n_steppers):
        steps = []
        for _s in range(int(rng.integers(1, 5))):
            occ = rng.random((int(rng.integers(1, 4)),) + cell) < 0.5
            if rng.random() < 0.75:
                boxes = tuple(
                    tuple(int(v) for v in rng.integers(1, 5, size=3))
                    for _ in range(int(rng.integers(1, 4))))
                steps.append(("multibox", occ, boxes))
            else:
                steps.append(("free_counts", occ, None))
        plans.append(steps)
    return plans


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.sampled_from([0.25, 0.5, 0.75, 1.0]),
       st.sampled_from([-1, 0, 1, 3]))   # -1: no deadline; ms otherwise
def test_schedules_byte_identical_under_random_interleaving(
        seed, quorum, timeout_ms):
    """The tentpole parity proof, extended to continuous scheduling:
    across randomized stepper interleavings, quorum fractions, and
    timeout firings (0 ms forces a deadline flush on every tick), every
    query's answer is byte-identical to the sequential per-task oracle
    (the inline engine call on the same inputs) — which round answered
    it cannot leak into the result."""
    timeout = None if timeout_ms < 0 else timeout_ms / 1000.0
    rng = np.random.default_rng(seed)
    cell = tuple(int(v) for v in rng.integers(3, 7, size=3))
    n = int(rng.integers(2, 5))
    plans = _random_query_plan(rng, cell, n)
    eng = ops.get_engine("numpy")
    broker = QueryBroker(eng, quorum=quorum, timeout=timeout)
    outs = [[] for _ in range(n)]
    errs = []

    def stepper(i):
        r = np.random.default_rng(seed ^ (i + 1))
        try:
            for kind, occ, boxes in plans[i]:
                time.sleep(float(r.random()) * 0.002)  # interleave
                if kind == "multibox":
                    outs[i].append(broker.multibox(occ, boxes))
                else:
                    outs[i].append(broker.free_counts(occ))
        except BaseException as e:  # noqa: BLE001 — surfaced below
            errs.append(e)
        finally:
            broker.deactivate()

    for _ in range(n):
        broker.register()
    threads = [threading.Thread(target=stepper, args=(i,), daemon=True)
               for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert not errs and not any(t.is_alive() for t in threads)
    for i, steps in enumerate(plans):
        for (kind, occ, boxes), got in zip(steps, outs[i]):
            if kind == "multibox":
                ref = np.asarray(eng.multibox(occ, boxes))
                np.testing.assert_array_equal(np.asarray(got) != 0,
                                              ref != 0)
            else:
                np.testing.assert_array_equal(
                    got, np.asarray(eng.free_counts(occ)))
    assert broker.stats.requests == sum(len(p) for p in plans)


def test_interleaving_parity_on_compiled_engine_with_padding():
    """Same contract through the jax path: bucketed box tables, padded
    B, fused free counts and the content cache all stay invisible in
    the answers."""
    seed = 1234
    rng = np.random.default_rng(seed)
    cell = (5, 5, 5)
    n = 3
    plans = _random_query_plan(rng, cell, n)
    oracle = ops.get_engine("numpy")
    broker = QueryBroker("jax", quorum=0.5, timeout=0.003)
    outs = [[] for _ in range(n)]

    def stepper(i):
        r = np.random.default_rng(seed ^ (i + 1))
        try:
            for kind, occ, boxes in plans[i]:
                time.sleep(float(r.random()) * 0.002)
                if kind == "multibox":
                    outs[i].append(broker.multibox(occ, boxes))
                else:
                    outs[i].append(broker.free_counts(occ))
        finally:
            broker.deactivate()

    for _ in range(n):
        broker.register()
    threads = [threading.Thread(target=stepper, args=(i,), daemon=True)
               for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not any(t.is_alive() for t in threads)
    for i, steps in enumerate(plans):
        for (kind, occ, boxes), got in zip(steps, outs[i]):
            if kind == "multibox":
                ref = oracle.multibox(occ, boxes)
                np.testing.assert_array_equal(np.asarray(got) != 0,
                                              ref != 0)
            else:
                np.testing.assert_array_equal(
                    np.asarray(got),
                    np.asarray(oracle.free_counts(occ)))


# ------------------------------------------------- chunking / sizing
def test_task_grid_bucket_defaults_mirror_make_policy():
    tasks = _tasks(runs=1)
    buckets = {t.label: task_grid_bucket(t) for t in tasks}
    assert buckets["RFold (4^3)"] == ("cube", 4)
    assert buckets["Folding (8^3)"] == ("static", (8, 8, 8))
    t = make_tasks([("x", "folding", {})], runs=1, num_jobs=5, load=1.0,
                   seed0=0)[0]
    assert task_grid_bucket(t) == ("static", (16, 16, 16))


def test_make_fleet_chunks_groups_buckets_and_caps_size():
    tasks = _tasks(runs=3)             # 6 cube tasks + 6 static tasks
    chunks = make_fleet_chunks(tasks, list(range(len(tasks))), 4)
    assert sorted(i for c in chunks for i in c) == list(range(len(tasks)))
    for chunk in chunks:
        assert len(chunk) <= 4
        assert len({task_grid_bucket(tasks[i]) for i in chunk}) == 1


def test_auto_fleet_size_scales_with_pending_and_workers():
    r = EvalRunner(workers=2, fleet_size="auto", fleet_engine="jax")
    assert r._resolve_fleet_size(24) == 3     # ceil(24 / (4*2))
    assert r._resolve_fleet_size(800) == 8    # capped
    assert r._resolve_fleet_size(2) == 2      # floor
    assert EvalRunner(workers=2,
                      fleet_size=6)._resolve_fleet_size(24) == 6
    assert EvalRunner(workers=2,
                      fleet_size=0)._resolve_fleet_size(24) is None


def test_fleet_mode_is_the_unconditional_default():
    """Fleet batching is the default on every engine — the host numpy
    path included (its multibox is genuinely (B, K) vectorized; the
    parity section of BENCH_fleet.json tracks the margin). The
    per-task oracle path is an explicit opt-out (fleet_size=0/None)."""
    assert EvalRunner(workers=2)._resolve_fleet_size(24) == 3
    assert EvalRunner(workers=2, fleet_size="auto",
                      fleet_engine="numpy")._resolve_fleet_size(24) == 3
    assert EvalRunner(workers=2, fleet_size="auto",
                      fleet_engine="pallas")._resolve_fleet_size(24) == 3
    assert EvalRunner(workers=2,
                      fleet_size=None)._resolve_fleet_size(24) is None


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
