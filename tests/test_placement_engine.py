"""Parity + cache-invalidation tests for the incremental placement
engine (vectorized place_fold, epoch caches, gated simulator).

The engine must be behavior-preserving: identical placement decisions
and SimResults on fixed seeds versus the retained naive path."""
import numpy as np
import pytest

from repro.core import fitmask
from repro.core.allocator import make_policy
from repro.core.folding import _verify_fold_reference, enumerate_folds
from repro.core.geometry import JobShape
from repro.core.reconfig import ReconfigTorus
from repro.core.torus import StaticTorus
from repro.sim.simulator import Simulator
from repro.traces.generator import TraceConfig, generate_trace

POLICY_MATRIX = [
    ("firstfit", dict(dims=(8, 8, 8))),
    ("folding", dict(dims=(8, 8, 8))),
    ("reconfig", dict(num_xpus=512, cube_n=4)),
    ("rfold", dict(num_xpus=512, cube_n=4)),
    ("rfold_be", dict(num_xpus=512, cube_n=4)),
]


def _job_sig(res):
    return [(j.job_id, j.start, j.finish, j.dropped, j.slowdown,
             j.placement_meta) for j in res.jobs]


# ------------------------------------------------------------- sim parity
@pytest.mark.parametrize("name,kw", POLICY_MATRIX)
def test_backfill_watermark_parity(name, kw):
    """Backfill + per-shape feasibility watermark == backfill with the
    naive retry-every-job drain: byte-identical job records, utilization
    samples and JCR on seeded traces (a shape that failed to place can
    only be unblocked by a completion, so skipping its retries until
    then must not change any scheduling decision)."""
    for seed, load in [(7, 1.5), (11, 2.5)]:
        cfg = TraceConfig(num_jobs=50, seed=seed, target_load=load)
        gated = Simulator(make_policy(name, **kw), generate_trace(cfg),
                          backfill=True, gated=True).run()
        naive = Simulator(make_policy(name, **kw), generate_trace(cfg),
                          backfill=True, gated=False).run()
        assert _job_sig(gated) == _job_sig(naive)
        assert gated.utilization_samples == naive.utilization_samples
        assert gated.jcr == naive.jcr


def test_backfill_watermark_clears_on_completion():
    """After a completion frees capacity, previously-infeasible shapes
    must be retried (the watermark resets): a big job blocked behind a
    long-running one starts as soon as the cluster drains."""
    from repro.sim.job import Job
    from repro.core.geometry import JobShape
    jobs = [Job(0, 0.0, duration=10.0, shape=JobShape((8, 8, 4))),
            Job(1, 1.0, duration=5.0, shape=JobShape((8, 8, 8))),
            Job(2, 2.0, duration=1.0, shape=JobShape((2, 2, 2)))]
    res = Simulator(make_policy("rfold", num_xpus=512, cube_n=4), jobs,
                    backfill=True, gated=True).run()
    by_id = {j.job_id: j for j in res.jobs}
    assert by_id[2].start == pytest.approx(2.0)    # backfilled past job 1
    assert by_id[1].start == pytest.approx(10.0)   # retried at completion
    assert res.jcr == 1.0


@pytest.mark.parametrize("name,kw", POLICY_MATRIX)
def test_simulator_parity_fast_vs_naive(name, kw):
    """Fast engine + gated drain == naive engine + ungated drain:
    byte-identical job records, utilization samples and JCR."""
    cfg = TraceConfig(num_jobs=40, seed=7, target_load=1.5)

    fast = make_policy(name, **kw)
    res_fast = Simulator(fast, generate_trace(cfg), gated=True).run()

    naive = make_policy(name, **kw)
    naive.use_naive = True  # no-op for static policies
    res_naive = Simulator(naive, generate_trace(cfg), gated=False).run()

    assert _job_sig(res_fast) == _job_sig(res_naive)
    assert res_fast.utilization_samples == res_naive.utilization_samples
    assert res_fast.jcr == res_naive.jcr


def _random_fill(rt: ReconfigTorus, rng, steps=18):
    """Drive the torus into a random occupancy via real commit/release."""
    live = []
    jid = 0
    for _ in range(steps):
        if live and rng.uniform() < 0.4:
            rt.release(live.pop(int(rng.integers(len(live)))))
            continue
        dims = tuple(int(rng.integers(1, 9)) for _ in range(3))
        for f in enumerate_folds(JobShape(dims), max_dim=rt.max_extent):
            plan = rt.place_fold(f)
            if plan is not None:
                rt.commit(jid, plan)
                live.append(jid)
                jid += 1
                break
    return live


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("offset_search", [True, False])
def test_place_fold_parity_random_occupancy(seed, offset_search):
    rng = np.random.default_rng(seed)
    rt = ReconfigTorus(512, 4)
    _random_fill(rt, rng)
    for dims in [(8, 4, 4), (18, 1, 1), (4, 8, 2), (6, 6, 1), (3, 3, 3),
                 (16, 2, 2)]:
        for f in enumerate_folds(JobShape(dims), max_dim=rt.max_extent):
            assert rt.place_fold(f, offset_search=offset_search) == \
                rt.place_fold_naive(f, offset_search=offset_search), (dims, f)


@pytest.mark.parametrize("name,kw", [("reconfig", dict(num_xpus=512, cube_n=4)),
                                     ("rfold", dict(num_xpus=512, cube_n=4)),
                                     ("rfold_be", dict(num_xpus=512, cube_n=4)),
                                     ("rfold", dict(num_xpus=512, cube_n=2))])
def test_can_ever_place_analytic_matches_naive(name, kw):
    rng = np.random.default_rng(42)
    fast = make_policy(name, **kw)
    naive = make_policy(name, **kw)
    naive.use_naive = True
    shapes = [tuple(int(rng.integers(1, 12)) for _ in range(3))
              for _ in range(40)] + [(8, 8, 8), (64, 1, 1), (9, 9, 9)]
    for dims in shapes:
        s = JobShape(dims)
        assert fast.can_ever_place(s) == naive.can_ever_place(s), dims


# ----------------------------------------------------- epoch invalidation
@pytest.mark.parametrize("seed", [0, 5, 9])
def test_epoch_cache_commit_release_roundtrip(seed):
    """commit -> release returns every cached query to its pre-commit
    answer (the epoch counter must invalidate correctly both ways)."""
    rng = np.random.default_rng(seed)
    rt = ReconfigTorus(512, 4)
    _random_fill(rt, rng, steps=6)
    probe = [f for s in [(8, 4, 4), (2, 2, 2), (4, 1, 1)]
             for f in enumerate_folds(JobShape(s), max_dim=rt.max_extent)]
    local = ((0, 2), (0, 4), (0, 4))
    before_mask = rt._block_free_mask(local).copy()
    before_plans = [rt.place_fold(f) for f in probe]
    victim = next(p for p in before_plans if p is not None)

    rt.commit(999, victim)
    during_mask = rt._block_free_mask(local)
    during_plans = [rt.place_fold(f) for f in probe]
    # the commit must be visible through the cache
    assert rt.busy_xpus == int(rt.occ.sum())
    assert during_plans != before_plans or not np.array_equal(
        before_mask, during_mask)

    rt.release(999)
    assert np.array_equal(rt._block_free_mask(local), before_mask)
    assert [rt.place_fold(f) for f in probe] == before_plans
    assert rt.busy_xpus == int(rt.occ.sum())
    rt.check_invariants()


def test_static_torus_epoch_cache_roundtrip():
    t = StaticTorus((8, 8, 8))
    before = {b: t.find_free_box(b) for b in [(8, 8, 8), (2, 2, 2), (4, 4, 1)]}
    t.commit_box(1, (0, 0, 0), (4, 4, 4))
    assert t.find_free_box((8, 8, 8)) is None   # cache saw the commit
    assert t.busy_xpus == 64
    t.release(1)
    for b, origin in before.items():
        assert t.find_free_box(b) == origin
    assert t.busy_xpus == 0
    t.check_invariants()


def test_bump_epoch_after_direct_mutation():
    rt = ReconfigTorus(128, 4)
    fold = enumerate_folds(JobShape((4, 4, 4)), max_dim=8)[0]
    assert rt.place_fold(fold) is not None      # caches built while empty
    rt.occ[:, :, :, :] = True                   # direct mutation...
    rt.bump_epoch()                             # ...must be announced
    assert rt.place_fold(fold) is None
    assert rt.busy_xpus == 128


# -------------------------------------------------------- verify / fitmask
def test_vectorized_verify_matches_reference():
    wraps = [(False, False, False), (True, True, True), (True, False, False),
             (False, True, True)]
    for dims in [(18, 1, 1), (4, 8, 2), (6, 4, 1), (3, 3, 3), (12, 2, 2),
                 (2, 2, 2), (5, 1, 1)]:
        for f in enumerate_folds(JobShape(dims), max_dim=64):
            for w in wraps:
                assert _verify_fold_impl_fresh(f, w) == \
                    _verify_fold_reference(f, w), (f, w)


def _verify_fold_impl_fresh(fold, wrap):
    from repro.core.folding import _verify_fold_impl
    return _verify_fold_impl(fold, wrap)


def test_fit_mask_batched_matches_single():
    rng = np.random.default_rng(3)
    occ = rng.uniform(size=(5, 6, 6, 6)) < 0.3
    for box in [(1, 1, 1), (2, 3, 1), (4, 4, 4), (6, 6, 6), (7, 1, 1)]:
        batched = fitmask.fit_mask_batched(occ, box)
        for i in range(occ.shape[0]):
            assert np.array_equal(batched[i], fitmask.fit_mask(occ[i], box))


def test_integral_image_block_queries():
    rng = np.random.default_rng(4)
    occ = rng.uniform(size=(7, 4, 4, 4)) < 0.4
    ii = fitmask.batched_integral_image(occ)
    for _ in range(30):
        lo = rng.integers(0, 4, size=3)
        hi = [int(rng.integers(l + 1, 5)) for l in lo]
        local = tuple((int(l), h) for l, h in zip(lo, hi))
        ref = np.array([occ[i][tuple(slice(l, h) for l, h in local)].sum()
                        for i in range(occ.shape[0])])
        assert np.array_equal(fitmask.block_sums_from_ii(ii, local), ref)
